"""Online router: admission -> scale-up -> crash-requeue -> drain.

Real prefill/decode through a shared Engine (smoke model), deterministic
virtual clock (modeled round times). The big invariants:

  * every admitted request completes with ordered timestamps;
  * autoscaling spawns replicas against backlog and drains them after;
  * replica crashes re-queue in-flight work which still completes;
  * ``engine.compile_count`` stays FLAT per replica — every replica hits
    the executable buckets the first one compiled;
  * the BENCH_4 headline: queue-depth beats fixed-1 on p99 TTFT under a
    burst at equal modeled cost (busy seconds are work-conserving);
  * calibration (``router/calibrate.py``): exact least-squares recovery,
    artifact round-trip, and LOUD errors when calibrated and hand-set
    round params are both supplied;
  * mesh slices: slice acquisition/release across scale-up → crash →
    drain never puts one slice (or device — slow 8-device test) in two
    live replicas, capacity clamps the policies, and per-slice engines
    keep compile counts flat across churn.
"""
import textwrap

import jax
import numpy as np
import pytest

from conftest import run_in_subprocess
from repro import configs
from repro.core import FaultInjector, LatencyModel
from repro.models import RunConfig, build
from repro.router import (ArrivalQueue, CalibratedLatencyModel,
                          CostCapPolicy, FixedReplicas, PoolSnapshot,
                          QueueConfig, QueueDepthPolicy, ReplicaConfig,
                          ReplicaPool, RoundSample, Router, RouterConfig,
                          ThroughputPolicy, bursty_arrivals,
                          diurnal_arrivals, fit_round_model, make_requests,
                          poisson_arrivals, samples_from_bench)
from repro.serving import Engine, Request

PROMPT, NEW, SLOTS, MAXLEN = 8, 4, 2, 16
LAT = LatencyModel(cold_start_s=0.3, per_item_s=0.05)


@pytest.fixture(scope="module")
def stack():
    cfg = configs.smoke("qwen2-7b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, RunConfig(cache_pad=8))
    return engine, params, cfg


def _requests(arrivals, cfg, **kw):
    return make_requests(arrivals, prompt_len=PROMPT, max_new_tokens=NEW,
                         vocab=cfg.vocab_size, seed=0, **kw)


def _run(engine, params, cfg, policy, arrivals, *, injector=None,
         queue_cfg=QueueConfig(), lat=LAT):
    pool = ReplicaPool(engine, params,
                       ReplicaConfig(n_slots=SLOTS, max_len=MAXLEN),
                       lat=lat, injector=injector or FaultInjector())
    router = Router(pool, policy, _requests(arrivals, cfg,
                                            deadline_s=
                                            queue_cfg.default_deadline_s),
                    queue_cfg=queue_cfg, traffic_name="test")
    return router.run(), router


# ---------------------------------------------------------------------------
# Traffic generators
# ---------------------------------------------------------------------------


def test_traffic_generators_sorted_bounded_deterministic():
    for gen in (poisson_arrivals, bursty_arrivals, diurnal_arrivals):
        a = gen(20.0, 5.0, seed=7)
        b = gen(20.0, 5.0, seed=7)
        assert np.array_equal(a, b)                      # same seed
        assert not np.array_equal(a, gen(20.0, 5.0, seed=8))
        assert np.all(np.diff(a) >= 0)                   # sorted
        assert a.size == 0 or (a[0] >= 0 and a[-1] < 5.0)


def test_zero_rate_or_horizon_yields_empty_trace():
    for gen in (poisson_arrivals, bursty_arrivals, diurnal_arrivals):
        assert gen(0.0, 5.0, seed=0).size == 0
        assert gen(10.0, 0.0, seed=0).size == 0


def test_bursty_concentrates_in_bursts():
    a = bursty_arrivals(40.0, 16.0, seed=0, burst_every_s=4.0,
                        burst_len_s=1.0)
    in_burst = ((a % 4.0) < 1.0).sum()
    assert in_burst > 0.7 * a.size  # 1/4 of the time holds >70% of load


# ---------------------------------------------------------------------------
# Arrival queue
# ---------------------------------------------------------------------------


def _req(rid, **kw):
    return Request(rid, np.ones(4, np.int32), max_new_tokens=2, **kw)


def test_queue_admission_cap_rejects():
    q = ArrivalQueue(QueueConfig(max_depth=2))
    assert q.submit(_req(0), 0.0) and q.submit(_req(1), 0.0)
    assert not q.submit(_req(2), 0.0)
    assert q.depth == 2 and len(q.rejected) == 1
    assert q.n_submitted == 3


def test_queue_deadline_expires_on_pop():
    q = ArrivalQueue(QueueConfig(default_deadline_s=1.0))
    q.submit(_req(0), 0.0)
    q.submit(_req(1), 1.5)
    assert q.pop(2.0).rid == 1        # rid 0 expired (2.0 - 0.0 > 1.0)
    assert [r.rid for r in q.expired] == [0]


def test_queue_requeue_at_front_resets_work():
    q = ArrivalQueue()
    for i in range(3):
        q.submit(_req(i), 0.0)
    q.pop(0.0)                        # rid 0 dispatched
    crashed = _req(0, arrival_t=0.0, first_token_t=0.5)
    crashed.generated = [1, 2]
    crashed.done = True
    q.requeue([crashed])
    assert q.n_requeued == 1
    first = q.pop(0.0)
    assert first.rid == 0             # back at the FRONT
    assert first.generated == [] and not first.done
    assert first.n_retries == 1
    assert first.first_token_t == 0.5  # the client saw that token


# ---------------------------------------------------------------------------
# Policies (pure snapshot math)
# ---------------------------------------------------------------------------


def _snap(**kw):
    base = dict(clock=0.0, queue_depth=0, oldest_wait_s=0.0, n_ready=1,
                n_starting=0, n_draining=0, active_slots=0,
                slots_per_replica=4, arrival_rate_rps=0.0, tokens_per_s=0.0,
                avg_request_tokens=10.0, cost_usd=0.0)
    base.update(kw)
    return PoolSnapshot(**base)


def test_queue_depth_policy_targets_backlog():
    p = QueueDepthPolicy(max_replicas=8)
    assert p.target(_snap()) == 1                       # min_replicas
    assert p.target(_snap(queue_depth=9, active_slots=3)) == 3
    assert p.target(_snap(queue_depth=1000)) == 8       # capped


def test_throughput_policy_targets_offered_rate():
    p = ThroughputPolicy(tokens_per_s_per_replica=50.0, max_replicas=8)
    assert p.target(_snap(arrival_rate_rps=4.0)) == 1   # 40 tok/s
    assert p.target(_snap(arrival_rate_rps=25.0)) == 5  # 250 tok/s


def test_cost_cap_policy_clamps_spend():
    inner = QueueDepthPolicy(max_replicas=8)
    p = CostCapPolicy(inner=inner, budget_usd=1.0,
                      price_per_replica_s=0.01, window_s=10.0,
                      max_replicas=8)
    rich = _snap(queue_depth=100, cost_usd=0.0)
    broke = _snap(queue_depth=100, cost_usd=0.99)
    assert p.target(rich) == 8          # budget affords the backlog
    assert p.target(broke) == 1         # cap bites -> min_replicas


# ---------------------------------------------------------------------------
# Router end-to-end
# ---------------------------------------------------------------------------


def test_admission_to_drain_single_replica(stack):
    engine, params, cfg = stack
    arrivals = poisson_arrivals(6.0, 2.0, seed=1)
    assert arrivals.size > 0
    report, router = _run(engine, params, cfg, FixedReplicas(n=1), arrivals)
    assert report.n_completed == report.n_submitted == arrivals.size
    assert report.n_rejected == report.n_expired == 0
    assert report.goodput == 1.0
    assert report.tokens_out == arrivals.size * NEW
    for r in router.completed:
        assert r.arrival_t <= r.first_token_t <= r.finish_t
        assert len(r.generated) == NEW
    # drained: every replica retired, clock covers the traffic horizon
    assert all(rep.state == "retired" for rep in router.pool.replicas)
    assert report.wall_time_s >= float(arrivals[-1])
    assert 0.0 < report.utilization <= 1.0
    assert report.cost_usd > 0


def test_scale_up_and_compile_count_flat_per_replica(stack):
    engine, params, cfg = stack
    # warm every executable bucket with a single-replica run
    warm = poisson_arrivals(4.0, 1.0, seed=2)
    _run(engine, params, cfg, FixedReplicas(n=1), warm)
    warm_compiles = engine.compile_count

    # a burst at t=0 forces queue-depth to spawn extra replicas
    burst = np.zeros(10)
    report, router = _run(engine, params, cfg,
                          QueueDepthPolicy(max_replicas=3), burst)
    assert report.peak_replicas >= 2          # it scaled
    assert report.n_spawns >= 2
    assert report.n_completed == 10
    # every replica (incl. freshly spawned) reused the warm executables
    assert engine.compile_count == warm_compiles, (
        "spawning replicas must not recompile: same cache/prompt buckets")
    assert all(rep.state == "retired" for rep in router.pool.replicas)


def test_crash_requeues_inflight_and_still_completes(stack):
    engine, params, cfg = stack
    arrivals = poisson_arrivals(6.0, 2.0, seed=3)
    injector = FaultInjector(seed=5, crash_prob=1.0, max_crashes=1)
    report, router = _run(engine, params, cfg, FixedReplicas(n=1),
                          arrivals, injector=injector)
    assert report.n_crashes == 1
    assert report.n_requeued >= 1
    # the crashed replica is dead; a replacement served the re-queued work
    states = [r.state for r in router.pool.replicas]
    assert states.count("dead") == 1
    assert report.n_spawns >= 2
    # retries are recorded and EVERY request still finished, exactly once
    assert report.n_completed == report.n_submitted == arrivals.size
    assert sum(r.n_retries for r in router.completed) >= 1
    assert sorted(r.rid for r in router.completed) == list(
        range(arrivals.size))
    assert report.tokens_out == arrivals.size * NEW


def test_queue_depth_beats_fixed1_on_burst_at_equal_cost(stack):
    """The BENCH_4 headline, pinned deterministically: an autoscaled pool
    collapses p99 TTFT under a burst while modeled busy seconds (and so
    cost) are work-conserving across policies."""
    engine, params, cfg = stack
    burst = np.zeros(12)              # 12 requests land at t=0
    fixed, _ = _run(engine, params, cfg, FixedReplicas(n=1), burst)
    auto, _ = _run(engine, params, cfg, QueueDepthPolicy(max_replicas=4),
                   burst)
    assert auto.n_completed == fixed.n_completed == 12
    p99_fixed = np.percentile(fixed.ttft_s, 99)
    p99_auto = np.percentile(auto.ttft_s, 99)
    assert p99_auto < 0.5 * p99_fixed
    # work conservation: identical busy seconds => identical bill
    assert auto.busy_replica_s == pytest.approx(fixed.busy_replica_s,
                                                rel=1e-9)
    assert auto.cost_usd <= fixed.cost_usd * (1 + 1e-6)


def test_admission_control_rejects_past_cap(stack):
    engine, params, cfg = stack
    burst = np.zeros(8)
    report, _ = _run(engine, params, cfg, FixedReplicas(n=1), burst,
                     queue_cfg=QueueConfig(max_depth=3))
    assert report.n_rejected > 0
    assert report.n_completed + report.n_rejected == report.n_submitted
    assert report.goodput < 1.0


def test_deadline_expiry_counts_against_goodput(stack):
    engine, params, cfg = stack
    burst = np.zeros(10)
    report, _ = _run(engine, params, cfg, FixedReplicas(n=1), burst,
                     queue_cfg=QueueConfig(default_deadline_s=0.6))
    # one replica at 0.05 s/token can't clear 10 requests in 0.6s
    assert report.n_expired > 0
    assert report.goodput < 1.0
    assert (report.n_completed + report.n_expired
            == report.n_submitted)


def test_drain_retirement_keeps_utilization_bounded(stack):
    """A replica finishing its last slot mid-drain must be retired at
    the round BOUNDARY, not the round start — otherwise its busy
    seconds exceed its ready window and utilization exceeds 1."""
    engine, params, cfg = stack
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(1, cfg.vocab_size, size=(4,),
                                    dtype=np.int32),
                    max_new_tokens=m, arrival_t=0.0)
            for i, m in enumerate([4, 12, 4, 12])]
    pool = ReplicaPool(engine, params,
                       ReplicaConfig(n_slots=2, max_len=MAXLEN), lat=LAT)
    router = Router(pool, QueueDepthPolicy(max_replicas=2), reqs,
                    traffic_name="test")
    report = router.run()
    assert report.n_completed == 4
    assert report.utilization <= 1.0 + 1e-9
    for rep in router.pool.replicas:
        assert rep.busy_s <= (rep.retire_t - rep.ready_t) + 1e-9


def test_measured_time_mode_runs(stack):
    engine, params, cfg = stack
    arrivals = poisson_arrivals(4.0, 1.0, seed=4)
    report, _ = _run(engine, params, cfg, FixedReplicas(n=1), arrivals,
                     lat=LatencyModel(cold_start_s=0.01, per_item_s=None))
    assert report.n_completed == arrivals.size
    assert report.busy_replica_s > 0   # measured host wall time


# ---------------------------------------------------------------------------
# Calibration (router/calibrate.py)
# ---------------------------------------------------------------------------


def _truth(p, a, overhead=0.004, per_item=0.02, factor=0.125):
    return overhead + per_item * (p * factor + a)


def _samples():
    pts = [(0, 1), (0, 2), (0, 4), (256, 0), (128, 2), (64, 8)]
    return [RoundSample(p, a, _truth(p, a)) for p, a in pts]


def test_fit_round_model_recovers_exact_params():
    cal = fit_round_model(_samples(), backend="cpu", device_count=1)
    assert cal.round_overhead_s == pytest.approx(0.004, abs=1e-9)
    assert cal.per_item_s == pytest.approx(0.02, abs=1e-9)
    assert cal.prefill_token_factor == pytest.approx(0.125, abs=1e-7)
    assert cal.rmse_s < 1e-10 and cal.max_abs_err_s < 1e-10
    assert cal.n_samples == 6
    # the model evaluates to what it was fitted on
    assert cal.round_seconds(64, 8) == pytest.approx(_truth(64, 8))


def test_fit_requires_three_rows():
    with pytest.raises(ValueError, match="3 measured rows"):
        fit_round_model(_samples()[:2])


def test_samples_from_bench_parses_sweep_rows():
    record = {"rows": [
        {"name": "serving/prefill_b8_s32", "us_per_call": 5000.0,
         "derived": "x"},
        {"name": "serving/decode_step_b1", "us_per_call": 900.0,
         "derived": "x"},
        {"name": "serving/mesh_decode_step_b8", "us_per_call": 1600.0,
         "derived": "x"},
        # mixed-phase rows must be skipped
        {"name": "serving/generate_b8_new32", "us_per_call": 1.0,
         "derived": "x"},
        {"name": "serving/slot_scheduler_64req", "us_per_call": 1.0,
         "derived": "x"},
    ]}
    samples = samples_from_bench(record)
    assert [(s.prefill_tokens, s.active_slots) for s in samples] == [
        (256, 0), (0, 1), (0, 8)]
    assert samples[0].seconds == pytest.approx(5e-3)


def test_calibration_artifact_roundtrip(tmp_path):
    cal = fit_round_model(_samples(), backend="cpu", device_count=1,
                          source="test")
    path = str(tmp_path / "calibration.json")
    cal.save(path)
    loaded = CalibratedLatencyModel.load(path)
    assert loaded == cal


def test_calibrated_and_hand_set_params_error_loudly():
    cal = fit_round_model(_samples())
    # hand-set round params alongside a calibration -> config refuses
    with pytest.raises(ValueError, match="BOTH a calibration"):
        RouterConfig(calibration=cal, round_overhead_s=0.1)
    with pytest.raises(ValueError, match="BOTH a calibration"):
        RouterConfig(calibration=cal, prefill_token_factor=0.5)
    # a pool LatencyModel.per_item_s alongside a calibration -> Router
    # refuses (the calibration carries the per-item term)
    with pytest.raises(ValueError, match="per_item_s"):
        cal.to_latency_model(per_item_s=0.01)


def test_calibrated_router_errors_on_hand_set_pool_per_item(stack):
    engine, params, cfg = stack
    cal = fit_round_model(_samples())
    pool = ReplicaPool(engine, params,
                       ReplicaConfig(n_slots=SLOTS, max_len=MAXLEN),
                       lat=LAT)   # LAT hand-sets per_item_s
    with pytest.raises(ValueError, match="per_item_s"):
        Router(pool, FixedReplicas(n=1), [],
               cfg=RouterConfig(calibration=cal))


def test_calibrated_router_completes_and_reports_mode(stack):
    engine, params, cfg = stack
    cal = fit_round_model(_samples())
    arrivals = poisson_arrivals(6.0, 2.0, seed=6)
    pool = ReplicaPool(engine, params,
                       ReplicaConfig(n_slots=SLOTS, max_len=MAXLEN),
                       lat=cal.to_latency_model(cold_start_s=0.3))
    router = Router(pool, QueueDepthPolicy(max_replicas=3),
                    _requests(arrivals, cfg), cfg=cal.to_router_config(),
                    traffic_name="test")
    report = router.run()
    assert report.time_model == "calibrated"
    assert report.n_completed == report.n_submitted == arrivals.size
    # the nonzero fitted round overhead must make busy seconds STRICTLY
    # exceed the overhead-free token-work total: each request commits
    # PROMPT·factor prefill work and NEW-1 slot-rounds (the admission
    # round yields the prefill token AND a decode token) — COST_MODEL.md
    pure_work = cal.per_item_s * report.n_completed * (
        PROMPT * cal.prefill_token_factor + NEW - 1)
    assert report.busy_replica_s > pure_work > 0


# ---------------------------------------------------------------------------
# Mesh-sliced replica pool (meshless degradation on the fast tier; the
# real 8-device mesh partition is the slow test below)
# ---------------------------------------------------------------------------


def _slice_pool(engine, params, n_slices, lat=LAT, injector=None):
    return ReplicaPool(engine, params,
                       ReplicaConfig(n_slots=SLOTS, max_len=MAXLEN),
                       lat=lat, injector=injector or FaultInjector(),
                       mesh_slices=n_slices)


def _assert_slice_lifetimes_disjoint(pool):
    """No slice may be held by two replicas with overlapping lifetimes."""
    by_slice = {}
    for r in pool.replicas:
        assert r.slice_idx is not None
        end = r.retire_t if r.retire_t is not None else float("inf")
        by_slice.setdefault(r.slice_idx, []).append((r.spawn_t, end))
    for spans in by_slice.values():
        spans.sort()
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert e0 <= s1 + 1e-9, "slice held by two live replicas"


def test_slice_capacity_clamps_policies_and_scale_up(stack):
    engine, params, cfg = stack
    burst = np.zeros(16)                 # demand wants 8 replicas
    pool = _slice_pool(engine, params, n_slices=2)
    router = Router(pool, QueueDepthPolicy(max_replicas=8),
                    _requests(burst, cfg), traffic_name="test")
    report = router.run()
    assert report.n_completed == 16
    assert report.n_slices == 2
    assert report.peak_replicas <= 2     # capacity clamps the policy
    assert pool.slices.held() == []      # every slice returned
    _assert_slice_lifetimes_disjoint(pool)


def test_slice_acquire_release_across_scale_crash_drain(stack):
    engine, params, cfg = stack
    arrivals = np.concatenate([np.zeros(8), np.full(4, 2.0)])
    injector = FaultInjector(seed=5, crash_prob=1.0, max_crashes=1)
    pool = _slice_pool(engine, params, n_slices=3, injector=injector)
    router = Router(pool, QueueDepthPolicy(max_replicas=8),
                    _requests(arrivals, cfg), traffic_name="test")
    report = router.run()
    assert report.n_crashes == 1
    assert report.n_completed == report.n_submitted == arrivals.size
    # the crashed replica's slice went back to the free pool and a
    # replacement (possibly on the SAME slice) served the re-queued work
    dead = [r for r in pool.replicas if r.state == "dead"]
    assert len(dead) == 1
    assert pool.slices.held() == []
    _assert_slice_lifetimes_disjoint(pool)
    # terminal states released every slice exactly once
    assert sorted(pool.slices._free) == list(range(3))


def test_slice_engines_compile_once_across_churn(stack):
    """Scale-up -> drain -> scale-up cycles must reuse each slice's
    cached engine: per-replica compile counts stay flat after warmup."""
    engine, params, cfg = stack
    pool = _slice_pool(engine, params, n_slices=2)
    warm = None
    for cycle in range(3):
        now = float(cycle * 10)
        pool.scale_to(2, now)
        pool.poll_ready(now + 1.0)
        for i, r in enumerate(pool.ready()):
            r.batcher.submit(Request(cycle * 10 + i,
                                     np.ones(PROMPT, np.int32),
                                     max_new_tokens=NEW))
            while r.n_inflight:
                r.step()
        count = pool.slices.compile_count()
        if cycle == 0:
            warm = count
        else:
            assert count == warm, (
                "re-acquiring a slice must reuse its cached engine")
        pool.scale_to(0, now + 9.0)
    assert pool.slices.held() == []


def test_slice_release_invariants():
    from repro.router import SlicePool
    cfg = configs.smoke("qwen2-7b")
    model = build(cfg)
    sp = SlicePool(Engine(model, RunConfig(cache_pad=8)), None, 2)
    a = sp.acquire()
    b = sp.acquire()
    assert {a, b} == {0, 1}
    assert sp.acquire() is None          # at capacity
    sp.release(a)
    with pytest.raises(ValueError, match="released"):
        sp.release(a)                    # double release is a bug
    assert sp.acquire() == a             # freed slice is reusable


@pytest.mark.slow
def test_mesh_slices_8dev_disjoint_devices_and_flat_compiles():
    """The real thing: an 8-device ("data","model") mesh cut into 4
    disjoint slices, each replica's engine on its own sub-mesh. No
    device ever belongs to two live slices, and scale-down/up churn
    never recompiles (acceptance criterion for the mesh_slices mode)."""
    run_in_subprocess(textwrap.dedent("""
        import numpy as np, jax
        from repro import configs
        from repro.core import LatencyModel
        from repro.models import RunConfig, build
        from repro.dist.sharding import slice_meshes
        from repro.launch.mesh import make_host_mesh
        from repro.router import (QueueDepthPolicy, ReplicaConfig,
                                  ReplicaPool, Router, make_requests)
        from repro.serving import Engine, Request

        assert jax.device_count() == 8
        mesh = make_host_mesh((4, 2), ("data", "model"))
        slices = slice_meshes(mesh, 4)
        ids = [sorted(d.id for d in s.devices.flat) for s in slices]
        flat = [i for s in ids for i in s]
        assert len(flat) == len(set(flat)) == 8, "slices overlap"
        assert all(dict(s.shape) == {"data": 1, "model": 2}
                   for s in slices), "slices must keep the model axis"

        cfg = configs.smoke("qwen2-7b")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = Engine(model, RunConfig(cache_pad=8), mesh=mesh)
        pool = ReplicaPool(engine, params,
                           ReplicaConfig(n_slots=2, max_len=16),
                           lat=LatencyModel(cold_start_s=0.1,
                                            per_item_s=0.05),
                           mesh_slices=4)
        warm = None
        for cycle in range(3):
            now = float(cycle * 10)
            pool.scale_to(4, now)
            pool.poll_ready(now + 1.0)
            dev = [d.id for r in pool.live()
                   for d in pool.slices.devices_of(r.slice_idx)]
            assert len(dev) == len(set(dev)), (
                "device in two live slices")
            for i, r in enumerate(pool.ready()):
                r.batcher.submit(Request(cycle * 10 + i,
                                         np.ones(8, np.int32),
                                         max_new_tokens=3))
                while r.n_inflight:
                    r.step()
            count = pool.slices.compile_count()
            if cycle == 0:
                warm = count
            else:
                assert count == warm, "slice churn recompiled"
            pool.scale_to(0, now + 9.0)
        assert pool.slices.held() == []

        # a full router run over the sliced pool also drains clean
        pool2 = ReplicaPool(engine, params,
                            ReplicaConfig(n_slots=2, max_len=16),
                            lat=LatencyModel(cold_start_s=0.1,
                                             per_item_s=0.05),
                            mesh_slices=4)
        reqs = make_requests(np.zeros(12), prompt_len=8,
                             max_new_tokens=4, vocab=cfg.vocab_size,
                             seed=0)
        report = Router(pool2, QueueDepthPolicy(max_replicas=8), reqs,
                        traffic_name="t").run()
        assert report.n_completed == 12
        assert report.n_slices == 4 and report.peak_replicas <= 4
        assert pool2.slices.held() == []
        print("MESH_SLICES_OK compiles=", warm)
    """))
