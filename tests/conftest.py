"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the default single
CPU device. Multi-device tests (dist/dryrun) spawn subprocesses that set
--xla_force_host_platform_device_count themselves.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_in_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet with N host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    return out.stdout
