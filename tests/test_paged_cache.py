"""Paged KV cache serving stack: allocator + engine + batcher.

Token-parity against the dense shared-cache path is the load-bearing
check: the paged batcher must be OBSERVATIONALLY identical to the dense
one (same greedy tokens for every request across admit/evict churn) —
pages, prefix sharing, and copy-on-write are pure memory-layout
optimizations. On top of that: warm-prefix admission actually shares
pages across COMPLETED requests, fork + COW isolates divergent
continuations, the compile-count stays flat under churn at exactly one
decode dispatch per round, and pool exhaustion requeues instead of
killing the round. Plus the bugfix-sweep regressions (falsy max_len,
reject-not-raise admission, expired-in-flight accounting).
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import RunConfig, build
from repro.router import ArrivalQueue, QueueConfig
from repro.serving import (ContinuousBatcher, Engine, PageAllocator,
                           PagesExhausted, Request)

PS = 8  # small pages so tiny prompts span several


@pytest.fixture(scope="module")
def small_lm():
    cfg = configs.smoke("qwen2-7b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(rng, n):
    return rng.integers(0, 250, size=(n,)).astype(np.int32)


def _run_batcher(model, params, reqs, **kw):
    eng = Engine(model, RunConfig(cache_pad=16))
    b = ContinuousBatcher(eng, params, **kw)
    for r in reqs:
        b.submit(r)
    b.run()
    return b, eng


# ---------------------------------------------------------------------------
# Paged == dense (observational equivalence)
# ---------------------------------------------------------------------------


def test_paged_matches_dense_tokens_under_churn(small_lm, rng):
    """6 mixed-length requests through 3 slots: the paged batcher emits
    exactly the dense batcher's greedy tokens, request by request."""
    _, model, params = small_lm
    prompts = [_prompt(rng, n) for n in (5, 11, 3, 17, 8, 13)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]

    dense, _ = _run_batcher(model, params, reqs(), n_slots=3, max_len=48)
    paged, _ = _run_batcher(model, params, reqs(), n_slots=3, max_len=48,
                            paged=True, page_size=PS)
    assert paged.paged, "paged mode silently fell back"
    d = {r.rid: r.generated for r in dense.scheduler.completed}
    p = {r.rid: r.generated for r in paged.scheduler.completed}
    assert p == d and len(p) == 6


def test_paged_falls_back_to_dense_under_mesh(small_lm):
    """Documented seq-shard fallback: a mesh-aware engine keeps the
    dense shared cache even when paged=True is requested."""
    _, model, params = small_lm
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    eng = Engine(model, RunConfig(cache_pad=16), mesh=mesh)
    b = ContinuousBatcher(eng, eng.shard_params(params), paged=True)
    assert not b.paged


# ---------------------------------------------------------------------------
# Prefix sharing (warm cache across completed requests)
# ---------------------------------------------------------------------------


def test_warm_prefix_shared_across_completed_requests(small_lm, rng):
    """Request B arrives AFTER request A (same 2-page prefix) completed:
    B's admission matches A's registered pages out of the reclaim pool
    (n_shared == 2) and still produces the dense-path tokens."""
    _, model, params = small_lm
    prefix = _prompt(rng, 2 * PS)
    tail_a, tail_b = _prompt(rng, 4), _prompt(rng, 4)
    pa = np.concatenate([prefix, tail_a])
    pb = np.concatenate([prefix, tail_b])

    eng = Engine(model, RunConfig(cache_pad=16))
    b = ContinuousBatcher(eng, params, n_slots=2, max_len=40, paged=True,
                          page_size=PS)
    b.submit(Request(rid=0, prompt=pa, max_new_tokens=2))
    b.run()

    plans = []
    real_admit = b.allocator.admit
    b.allocator.admit = lambda *a, **k: (plans.append(real_admit(*a, **k))
                                         or plans[-1])
    b.submit(Request(rid=1, prompt=pb, max_new_tokens=2))
    b.run()

    assert [p.n_shared for p in plans] == [2]
    assert plans[0].start_len == 2 * PS
    assert len(plans[0].suffix) == 4
    ref = eng.generate(params, pb[None], max_new_tokens=2)
    done = {r.rid: r.generated for r in b.scheduler.completed}
    assert done[1] == list(np.asarray(ref[0, len(pb):]))


def test_concurrent_rows_alias_prefix_pages(small_lm, rng):
    """Two LIVE rows with a common prompt prefix hold the same physical
    pages at refcount 2 — one copy in HBM, not two."""
    _, model, params = small_lm
    prefix = _prompt(rng, 2 * PS)
    pa = np.concatenate([prefix, _prompt(rng, 3)])
    pb = np.concatenate([prefix, _prompt(rng, 5)])
    eng = Engine(model, RunConfig(cache_pad=16))
    b = ContinuousBatcher(eng, params, n_slots=2, max_len=40, paged=True,
                          page_size=PS)
    b.submit(Request(rid=0, prompt=pa, max_new_tokens=8))
    b.submit(Request(rid=1, prompt=pb, max_new_tokens=8))
    b.step()  # both admitted, neither done yet
    alloc = b.allocator
    shared = set(alloc.rows[0]) & set(alloc.rows[1])
    assert len(shared) == 2
    assert all(alloc.refcount(p) == 2 for p in shared)
    b.run()
    assert len(b.scheduler.completed) == 2


# ---------------------------------------------------------------------------
# fork + copy-on-write
# ---------------------------------------------------------------------------


def test_fork_cow_divergence_matches_independent_decodes(small_lm, rng):
    """Best-of-N: fork row 0 into row 1 at zero copy cost, force
    different first tokens, and decode both in the SAME ragged
    dispatches. The COW barrier must fire on the shared partial tail
    page, and each row's continuation must equal the unforked
    single-request answer."""
    _, model, params = small_lm
    prompt = _prompt(rng, PS + 4)          # 1 full page + partial tail
    steps = 4
    eng = Engine(model, RunConfig(cache_pad=16))
    alloc = PageAllocator(n_pages=9, page_size=PS, max_pages=3)
    cache = eng.new_paged_cache(2, 9, PS, 3)

    plan = alloc.admit(0, prompt, steps + 1)
    cache = eng.assign_row_pages(cache, 0, plan.pages, plan.start_len)
    logits, cache = eng.extend_row(params, cache, 0, plan.suffix[None])
    t0 = int(np.argmax(np.asarray(logits[0])))
    t1 = (t0 + 1) % 250                    # forced divergent branch

    alloc.fork(0, 1)
    cache = eng.fork_row(cache, 0, 1)
    assert alloc.rows[0] == alloc.rows[1]
    host_len = {0: len(prompt), 1: len(prompt)}
    toks = np.array([[t0], [t1]], np.int32)
    out = {0: [t0], 1: [t1]}
    cow_fired = 0
    for _ in range(steps):
        for row in (0, 1):
            cow = alloc.writable_page(row, host_len[row])
            if cow is not None:
                cow_fired += 1
                cache = eng.cow_copy_page(cache, *cow)
                cache = eng.assign_row_pages(cache, row, alloc.rows[row],
                                             host_len[row])
        logits, cache = eng.decode(params, cache, toks)
        nxt = np.asarray(np.argmax(np.asarray(logits), axis=-1), np.int32)
        for row in (0, 1):
            out[row].append(int(nxt[row]))
            host_len[row] += 1
        toks = nxt[:, None]

    assert cow_fired == 1                  # exactly one tail-page split
    assert alloc.rows[0][1] != alloc.rows[1][1]  # tails diverged
    assert alloc.rows[0][0] == alloc.rows[1][0]  # full page still shared
    # each branch == the answer with no fork involved at all
    ref0 = eng.generate(params, prompt[None], max_new_tokens=steps + 1)
    assert out[0] == list(np.asarray(ref0[0, len(prompt):]))
    forced = np.concatenate([prompt, [t1]]).astype(np.int32)
    ref1 = eng.generate(params, forced[None], max_new_tokens=steps)
    assert out[1][1:] == list(np.asarray(ref1[0, len(forced):]))


# ---------------------------------------------------------------------------
# Compilation + dispatch accounting
# ---------------------------------------------------------------------------


def test_compile_count_flat_and_one_dispatch_per_round(small_lm, rng):
    """Admit/evict churn reuses executables: a second wave with the same
    request shapes adds ZERO compiles, and every scheduling round with
    active slots costs exactly one decode dispatch."""
    _, model, params = small_lm
    lens = (6, 10, 14)

    def wave(base):
        return [Request(rid=base + i, prompt=_prompt(rng, n),
                        max_new_tokens=3) for i, n in enumerate(lens)]

    eng = Engine(model, RunConfig(cache_pad=16))
    b = ContinuousBatcher(eng, params, n_slots=3, max_len=32, paged=True,
                          page_size=PS)
    for r in wave(0):
        b.submit(r)
    b.run()
    warm, rounds0, disp0 = eng.compile_count, b.rounds, b.decode_dispatches
    assert disp0 == rounds0
    for r in wave(10):
        b.submit(r)
    b.run()
    assert eng.compile_count == warm
    assert b.decode_dispatches - disp0 == b.rounds - rounds0
    assert len(b.scheduler.completed) == 6


# ---------------------------------------------------------------------------
# Pool exhaustion: transient -> requeue, permanent -> reject
# ---------------------------------------------------------------------------


def test_pages_exhausted_requeues_and_drains(small_lm, rng):
    """A pool sized for ONE row at a time: the second request waits at
    the queue front while the first holds every page, then runs to
    completion once the pages come back. No exception escapes step()."""
    _, model, params = small_lm
    reqs = [Request(rid=i, prompt=_prompt(rng, 12), max_new_tokens=3)
            for i in range(2)]
    eng = Engine(model, RunConfig(cache_pad=16))
    b = ContinuousBatcher(eng, params, n_slots=2, max_len=16, paged=True,
                          page_size=PS, n_pages=1 + 2)  # null + one row's 2
    for r in reqs:
        b.submit(r)
    b.run()
    assert sorted(r.rid for r in b.scheduler.completed) == [0, 1]
    assert b.take_rejected() == []


def test_paged_oversized_request_rejected_round_survives(small_lm, rng):
    """A request that can NEVER fit a row is rejected at admission while
    the concurrently-admitted request still completes (satellite of the
    dense-path fix, on the paged path)."""
    _, model, params = small_lm
    ok = Request(rid=0, prompt=_prompt(rng, 6), max_new_tokens=2)
    huge = Request(rid=1, prompt=_prompt(rng, 30), max_new_tokens=20)
    eng = Engine(model, RunConfig(cache_pad=16))
    b = ContinuousBatcher(eng, params, n_slots=2, max_len=24, paged=True,
                          page_size=PS)
    b.submit(ok)
    b.submit(huge)
    b.run()
    assert [r.rid for r in b.scheduler.completed] == [0]
    assert [r.rid for r in b.take_rejected()] == [1]


# ---------------------------------------------------------------------------
# Bugfix sweep regressions
# ---------------------------------------------------------------------------


def test_engine_rejects_falsy_and_undersized_max_len(small_lm):
    """max_len=0 used to silently fall through ``max_len or default`` and
    re-derive a default; now every non-positive or too-small capacity is
    a loud ValueError at the API boundary."""
    _, model, params = small_lm
    eng = Engine(model, RunConfig(cache_pad=16))
    toks = np.ones((1, 8), np.int32)
    with pytest.raises(ValueError, match="max_len"):
        eng.prefill(params, toks, max_len=0)
    with pytest.raises(ValueError, match="max_len"):
        eng.prefill(params, toks, max_len=4)  # prompt is 8 tokens
    with pytest.raises(ValueError):
        eng.new_cache(0, 32)
    with pytest.raises(ValueError):
        eng.new_cache(2, 0)
    with pytest.raises(ValueError):
        eng.new_paged_cache(2, 0, PS, 2)
    # and the None path still sizes prompt + cache_pad
    logits, cache = eng.prefill(params, toks, max_len=None)
    assert cache.layers[0]["k"].shape[2] == 8 + 16


def test_dense_late_long_prompt_rejected_not_raised(small_lm, rng):
    """The longest prompt arriving AFTER the shared cache is sized used
    to raise out of step() and kill the whole round. Now: rejected at
    admission; every other slot completes untouched."""
    _, model, params = small_lm
    eng = Engine(model, RunConfig(cache_pad=8))
    b = ContinuousBatcher(eng, params, n_slots=2)
    b.submit(Request(rid=0, prompt=_prompt(rng, 6), max_new_tokens=4))
    b.step()  # cache sized off the 6-token prompt: max_len = 14
    b.submit(Request(rid=1, prompt=_prompt(rng, 40), max_new_tokens=4))
    b.submit(Request(rid=2, prompt=_prompt(rng, 5), max_new_tokens=4))
    b.run()
    assert sorted(r.rid for r in b.scheduler.completed) == [0, 2]
    assert [r.rid for r in b.take_rejected()] == [1]


def test_requeue_expired_in_flight_counted_exactly_once():
    """A request whose deadline passed WHILE in flight on a crashed
    replica lands in ``expired`` exactly once: no retry tick, no
    n_requeued tick, never popped again."""
    q = ArrivalQueue(QueueConfig(drop_expired=True))
    dead = Request(rid=0, prompt=np.ones(4, np.int32), max_new_tokens=2,
                   arrival_t=0.0, deadline_s=1.0,
                   generated=[7], n_retries=0)
    alive = Request(rid=1, prompt=np.ones(4, np.int32), max_new_tokens=2,
                    arrival_t=9.5, deadline_s=10.0, generated=[8])
    n = q.requeue([dead, alive], now=10.0)
    assert n == 1 and q.n_requeued == 1
    assert q.expired == [dead]
    assert dead.n_retries == 0 and dead.generated == [7]  # no reset
    assert alive.n_retries == 1 and alive.generated == []  # reset+retried
    assert q.pop(10.0) is alive
    assert q.pop(10.0) is None
    assert q.expired == [dead]  # still exactly once


def test_requeue_without_now_keeps_legacy_semantics():
    """Callers that don't know the crash time keep the old behavior:
    everything is reset and requeued; ``pop`` does the expiring."""
    q = ArrivalQueue(QueueConfig(drop_expired=True))
    r = Request(rid=0, prompt=np.ones(4, np.int32), max_new_tokens=2,
                arrival_t=0.0, deadline_s=1.0)
    assert q.requeue([r]) == 1
    assert q.pop(99.0) is None  # expired on the way out
    assert q.expired == [r]
