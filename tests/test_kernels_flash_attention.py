"""Flash-attention Pallas kernel vs pure-jnp oracle (interpret mode).

Sweeps shapes, GQA ratios, dtypes, masks, windows, softcaps — per the
per-kernel allclose requirement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref

CASES = [
    # (b, s, t, h, kv, d, causal, window, cap)
    (1, 128, 128, 4, 2, 64, True, None, None),
    (2, 64, 64, 4, 4, 32, True, None, None),
    (1, 256, 256, 8, 2, 64, True, None, 50.0),
    (1, 128, 128, 4, 1, 64, True, 32, None),
    (2, 64, 128, 4, 2, 64, False, None, None),   # cross attn, t > s
    (1, 100, 100, 8, 2, 64, True, None, None),   # non-multiple: pad path
    (1, 96, 200, 2, 2, 128, False, None, 30.0),  # pad + bidir + cap
    (1, 128, 128, 4, 2, 192, True, None, None),  # nemotron head_dim
]


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    b, s, t, h, kv, d, causal, window, cap = case
    key = jax.random.PRNGKey(hash(case) % 2**31)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, s, h, d), dtype)
    k = jax.random.normal(k2, (b, t, kv, d), dtype)
    v = jax.random.normal(k3, (b, t, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, block_q=32, block_k=32,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_block_shape_independence():
    """Same result regardless of VMEM tile shape."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 128, 4, 64))
    k = jax.random.normal(key, (1, 128, 2, 64))
    v = jax.random.normal(key, (1, 128, 2, 64))
    outs = [
        flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


def test_fully_masked_rows_are_zero():
    """window=1 + causal: each row sees exactly itself (never NaN)."""
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 64, 2, 32))
    k = jax.random.normal(key, (1, 64, 2, 32))
    v = jax.random.normal(key, (1, 64, 2, 32))
    out = flash_attention(q, k, v, causal=True, window=1, block_q=32,
                          block_k=32, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    ref = flash_attention_ref(q, k, v, causal=True, window=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
