"""Quickstart: decompose a monolithic inference job into parallel
serverless-style functions and compare — the paper's idea in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import configs
from repro.core import (ArtifactStore, BatchJob, LatencyModel,
                        MonolithicConfig, MonolithicRunner, Orchestrator,
                        OrchestratorConfig, ServerlessFunction, decompose,
                        merge)
from repro.data import imdb_reviews
from repro.data.pipeline import DatasetRef
from repro.models import RunConfig, build
from repro.serving import Engine

# 1. a real model (reduced DistilBERT classifier) + real data
cfg = configs.smoke("distilbert-imdb")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = Engine(model, RunConfig())
tokens, labels = imdb_reviews(n=128, seq_len=32, vocab=cfg.vocab_size)

# 2. publish the model to the shared store (the EFS analogue)
store = ArtifactStore()
store.put_tree("models/clf", params)

# 3. define the batch job and decompose it into chunks
job = BatchJob("quickstart", DatasetRef("imdb", 128, 32, cfg.vocab_size),
               "models/clf", batch_size=16)
chunks = decompose(job)
lat = LatencyModel(cold_start_s=0.5, per_item_s=None)  # real compute


def make_worker(i):
    return ServerlessFunction(i, store, lat, engine=engine,
                              params_ref="models/clf")


# 4. monolithic baseline (one function, sequential batches)
mono = MonolithicRunner(store, MonolithicConfig()).run(
    job, chunks, make_worker, data={"tokens": tokens})

# 5. parallel functions via the Step-Functions-analogue orchestrator
par = Orchestrator(store, OrchestratorConfig(max_concurrency=8)).run(
    job, chunks, make_worker, data={"tokens": tokens})
preds = merge(store, job, chunks)

print(f"monolithic: {mono.wall_time_s:6.1f}s  ${mono.cost_usd:.6f}")
print(f"parallel:   {par.wall_time_s:6.1f}s  ${par.cost_usd:.6f}  "
      f"({par.n_invocations} functions)")
print(f"speedup {mono.wall_time_s / par.wall_time_s:.1f}x at "
      f"{par.cost_usd / mono.cost_usd:.2f}x cost; "
      f"accuracy={float((preds == labels).mean()):.3f}")
