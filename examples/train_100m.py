"""Train a ~100M-parameter LM for a few hundred steps with checkpointing
and an injected crash + automatic restart (deliverable b, training driver).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import dataclasses
import shutil

from repro import configs
from repro.launch import train as train_launch
from repro.models import build
from repro.models.common import LayerSpec, ModelConfig


def model_100m() -> ModelConfig:
    """~100M-parameter dense decoder (qwen2-family reduced)."""
    return dataclasses.replace(
        configs.get("qwen2-7b"),
        name="qwen2-100m",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32_000, max_position=4096)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt_100m")
    args = ap.parse_args()

    cfg = model_100m()
    # register so the launcher can find it by name
    configs.ARCHS[cfg.name] = cfg
    n = build(cfg).n_params
    print(f"model: {cfg.name}, {n/1e6:.1f}M params")
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    ckpt_every = max(min(50, args.steps // 4), 1)
    out = train_launch.main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq-len", str(args.seq_len),
        "--lr", "3e-4", "--warmup", "20",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", str(ckpt_every),
        "--log-every", "25",
        # exercise the fault-tolerance path: crash once mid-run, auto-resume
        "--crash-at-step", str(args.steps // 2),
        "--max-restarts", "2",
    ])
    if args.steps >= 100:  # loss descent only meaningful at real length
        assert out["final_loss"] < out["first_loss"], "loss must descend"
    print("done: crash injected at midpoint, training resumed from "
          "checkpoint, run completed.")
