"""Pod-style serving with fault injection: a mesh-aware engine drives LM
generation workers (continuous batching over sharded KV caches) while
crashes and stragglers are injected — demonstrates retries, speculation,
and exactly-once commits on a generative (non-classifier) workload.

The mesh spans every local device as the "model" axis, so on a pod the
decode caches are sequence-sharded over the chips (the
``dist.collectives`` fused path) while on a 1-CPU container the same
code degrades to single-device serving.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import jax
import numpy as np

from repro import configs
from repro.core import (ArtifactStore, BatchJob, FaultInjector,
                        LatencyModel, Orchestrator, OrchestratorConfig,
                        ElasticPolicy, ServerlessFunction, decompose)
from repro.data.pipeline import DatasetRef
from repro.launch.mesh import make_host_mesh
from repro.models import RunConfig, build
from repro.serving import ContinuousBatcher, Engine, Request

cfg = configs.smoke("qwen2-7b")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
mesh = make_host_mesh((1, jax.device_count()), ("data", "model"))
engine = Engine(model, RunConfig(cache_pad=64), mesh=mesh, seq_shard=True)
params = engine.shard_params(params)

# --- continuous batching demo on real sharded decode steps -----------------
print(f"== continuous batching: 24 generation requests over 4 slots "
      f"(mesh {dict(mesh.shape)}) ==")
batcher = ContinuousBatcher(engine, params, n_slots=4)
rng = np.random.default_rng(0)
for rid in range(24):
    batcher.submit(Request(rid, rng.integers(0, cfg.vocab_size, 8),
                           max_new_tokens=int(rng.integers(4, 12))))
completed = batcher.run()
print(f"  completed {len(completed)} requests: {batcher.decode_steps} "
      f"slot-steps of decode in only {batcher.decode_dispatches} batched "
      f"dispatches ({batcher.rounds} rounds — ONE shared ragged KV cache, "
      f"one dispatch per round) across {len(engine._exec)} compiled "
      f"executables; the cache stays in the cache_shardings layout "
      f"through every admit/evict")

# --- orchestrated generation job under faults -------------------------------
print("\n== orchestrated generation job with injected faults ==")
prompts = rng.integers(0, cfg.vocab_size, size=(96, 8)).astype(np.int32)
store = ArtifactStore()
store.put_tree("models/lm", params)
job = BatchJob("gen", DatasetRef("prompts", len(prompts), 8,
                                 cfg.vocab_size), "models/lm", 12)
chunks = decompose(job)
lat = LatencyModel(cold_start_s=0.3, per_item_s=None)


class GenWorker(ServerlessFunction):
    """A worker whose payload is generation, not classification."""

    def invoke(self, job, chunk, data=None):
        import time
        cold = not self.warm
        start_s = (self.latency.cold_start_s if cold
                   else self.latency.warm_start_s)
        load_s = self._cold_load() if cold else 0.0
        self.warm = True
        t0 = time.perf_counter()
        out = engine.generate(self._params if self._params is not None
                              else params,
                              data["prompts"][chunk.start:chunk.end],
                              max_new_tokens=4)
        compute_s = time.perf_counter() - t0
        from repro.core.job import InvokeOutcome
        return InvokeOutcome(
            duration_s=self.latency.invoke_overhead_s + start_s + load_s
            + compute_s + self.latency.result_write_s,
            payload={"predictions": out[:, -4:].sum(-1)},  # digest
            cold_start=cold, max_ram_mb=self.ram_mb, compute_s=compute_s,
            load_s=load_s)


orch = Orchestrator(
    store,
    OrchestratorConfig(max_concurrency=4, retry_max_attempts=5,
                       speculation_factor=3.0,
                       elastic=ElasticPolicy(min_concurrency=4,
                                             max_concurrency=16,
                                             scale_step=4)),
    injector=FaultInjector(seed=7, crash_prob=0.15, straggler_prob=0.1,
                           straggler_factor=8.0))
report = orch.run(job, chunks,
                  lambda i: GenWorker(i, store, lat, engine=engine,
                                      params_ref="models/lm"),
                  data={"prompts": prompts})
print(f"  chunks committed: {report.extra['committed']}/{len(chunks)}")
print(f"  crashes={report.n_crashes} retries={report.n_retries} "
      f"speculative={report.n_speculative} "
      f"final_concurrency={report.extra['final_concurrency']}")
print(f"  wall={report.wall_time_s:.1f}s billed={report.total_billed_s:.1f}s "
      f"cost=${report.cost_usd:.6f}")
assert report.extra["committed"] == len(chunks), "job must complete"
scale_ups = [e for e in orch.events if e["kind"] == "scale_up"]
print(f"  elastic scale-ups: {len(scale_ups)}")
