"""The paper's case study, end to end (deliverable b, serving driver):

  1. TRAIN the DistilBERT-family classifier on synthetic IMDb until it
     separates the classes (real training, this host),
  2. serve the full dataset monolithically vs in parallel with REAL
     inference through the orchestrator,
  3. reproduce the paper-scale Fig. 2 sweep with the calibrated simulator
     and validate the headline claims.

    PYTHONPATH=src python examples/sentiment_case_study.py
"""
import jax
import numpy as np

from repro import configs
from repro.core import (ArtifactStore, BatchJob, LatencyModel,
                        MonolithicConfig, MonolithicRunner, Orchestrator,
                        OrchestratorConfig, ServerlessFunction, decompose,
                        merge)
from repro.core.simulator import CaseStudyConfig, run_monolithic, run_parallel
from repro.data import TrainLoader, imdb_reviews
from repro.data.pipeline import DatasetRef
from repro.models import RunConfig, build
from repro.serving import Engine
from repro.training.optimizer import AdamW, constant
from repro.training.train_step import make_train_step

RUN = RunConfig()

# --- 1. train the classifier on the planted-signal IMDb ------------------
print("== training sentiment classifier ==")
cfg = configs.smoke("distilbert-imdb")
model = build(cfg)
tokens, labels = imdb_reviews(n=512, seq_len=48, vocab=cfg.vocab_size,
                              signal_frac=0.15)
params = model.init(jax.random.PRNGKey(0))
opt = AdamW(schedule=constant(3e-3), weight_decay=0.0)
opt_state = opt.init(params)
step = jax.jit(make_train_step(model, RUN, opt))
loader = TrainLoader(tokens[:384], labels[:384], batch=32)
for i in range(150):
    params, opt_state, m = step(params, opt_state, loader.next_batch())
    if (i + 1) % 30 == 0:
        print(f"  step {i+1}: loss={float(m['loss']):.4f} "
              f"acc={float(m['accuracy']):.3f}")

engine = Engine(model, RUN)
test_tokens, test_labels = tokens[384:], labels[384:]
acc = float((engine.classify(params, test_tokens) == test_labels).mean())
print(f"  held-out accuracy: {acc:.3f}")

# --- 2. monolithic vs parallel on REAL inference --------------------------
print("\n== real serving: monolithic vs parallel (128 held-out items) ==")
store = ArtifactStore()
store.put_tree("models/clf", params)
job = BatchJob("case", DatasetRef("imdb", len(test_tokens), 48,
                                  cfg.vocab_size), "models/clf", 16)
chunks = decompose(job)
lat = LatencyModel(cold_start_s=0.5, per_item_s=None)


def mk(i):
    return ServerlessFunction(i, store, lat, engine=engine,
                              params_ref="models/clf")


data = {"tokens": test_tokens}
mono = MonolithicRunner(store, MonolithicConfig()).run(job, chunks, mk,
                                                       data=data)
par = Orchestrator(store, OrchestratorConfig(max_concurrency=8)).run(
    job, chunks, mk, data=data)
preds = merge(store, job, chunks)
assert (preds == engine.classify(params, test_tokens)).all(), \
    "parallel decomposition must preserve monolithic semantics"
print(f"  monolithic {mono.wall_time_s:5.1f}s ${mono.cost_usd:.6f} | "
      f"parallel {par.wall_time_s:5.1f}s ${par.cost_usd:.6f} | "
      f"speedup {mono.wall_time_s/par.wall_time_s:.1f}x, semantics exact")

# --- 3. paper-scale calibrated sweep (Fig 2) -------------------------------
print("\n== paper-scale sweep (25k reviews, calibrated platform) ==")
cs = CaseStudyConfig()
print(f"{'bs':>5} {'mono_min':>9} {'mono_$':>8} {'par_min':>8} "
      f"{'par_$':>8} {'fns':>5} {'reduction':>9}")
for bs in [50, 100, 250, 500, 1000]:
    m = run_monolithic(cs, bs)
    p = run_parallel(cs, bs)
    print(f"{bs:>5} {m.wall_time_s/60:>9.1f} {m.cost_usd:>8.4f} "
          f"{p.wall_time_s/60:>8.2f} {p.cost_usd:>8.4f} "
          f"{p.n_invocations:>5} "
          f"{100*(1-p.wall_time_s/m.wall_time_s):>8.1f}%")
print("\npaper claims: >95% time reduction at comparable cost — "
      "see EXPERIMENTS.md §Fig2 for the full validation")
